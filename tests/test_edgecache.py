"""Out-of-core topology: the device edge-block cache + cached sampling
kernel.  The acceptance bar is bit-identity — pallas training through an
HBM edge-block cache smaller than the edge array must match the
full-edge-array-upload path exactly, with both cache counter families
reported in the batch trace."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BackendSpec, CacheTierSpec, GNNConfig, GraphSAGE,
                        PipelineSpec, SamplerSpec, StoreSpec, build_pipeline,
                        build_train_step, make_loader, train_loop)
from repro.kernels import ops
from repro.optim import adamw
from repro.storage import (DeviceEdgeBlockCache, DiskStore, edge_block_count,
                           save_graph)

FANOUTS = (3, 2)
BATCH = 8


@pytest.fixture(scope="module")
def disk_dir(small_graph, tmp_path_factory):
    path = tmp_path_factory.mktemp("graphstore-edge")
    save_graph(small_graph, str(path))
    return str(path)


def _edge_tier(blocks, rows=0, policy="lru"):
    arrays = (("features",) if rows else ()) + ("topology",)
    return CacheTierSpec(tier="device", rows=rows, edge_blocks=blocks,
                         policy=policy, arrays=arrays)


# ---------------------------------------------------------------------------
# DeviceEdgeBlockCache core
# ---------------------------------------------------------------------------

def test_block_contents_match_padded_edge_array(small_graph):
    g = small_graph
    block_e = ops.edge_block_size(int(g.degrees().max()))
    nb = edge_block_count(g.num_edges, block_e)
    dc = DeviceEdgeBlockCache(g, indptr=g.indptr, block_e=block_e,
                              blocks=8)
    want_all = np.zeros(nb * block_e, np.int32)
    want_all[:g.num_edges] = g.indices
    for blocks in ([0, 1], [nb - 2, nb - 1], [3, 4, 5]):
        dc.resolve(np.asarray(blocks))
        table = np.asarray(dc.table)
        slots = np.asarray(dc.slot_of)
        for b in blocks:
            np.testing.assert_array_equal(
                table[slots[b]], want_all[b * block_e:(b + 1) * block_e],
                err_msg=f"block {b}")


def test_plan_fits_budget_and_covers_padding(small_graph):
    g = small_graph
    block_e = ops.edge_block_size(int(g.degrees().max()))
    dc = DeviceEdgeBlockCache(g, indptr=g.indptr, block_e=block_e,
                              blocks=5)
    rng = np.random.default_rng(0)
    targets = rng.integers(0, g.num_nodes, 64)
    chunks = dc.plan(targets)
    covered = 0
    for sl, blocks in chunks:
        nonpinned = np.count_nonzero(~dc._pinned_mask[blocks])
        assert nonpinned <= dc._lru_capacity
        assert 0 in blocks and 1 in blocks       # tile-padding pair
        seg = targets[sl]
        b0 = np.minimum(g.indptr[seg] // block_e, dc.max_block)
        assert set(b0) | set(b0 + 1) <= set(blocks.tolist())
        covered += seg.size
    assert covered == targets.size


def test_too_small_edge_cache_raises(small_graph):
    g = small_graph
    block_e = ops.edge_block_size(int(g.degrees().max()))
    with pytest.raises(ValueError, match="4 non-pinned"):
        DeviceEdgeBlockCache(g, indptr=g.indptr, block_e=block_e, blocks=3)
    with pytest.raises(ValueError, match="4 non-pinned"):
        DeviceEdgeBlockCache(g, indptr=g.indptr, block_e=block_e, blocks=6,
                             policy="pinned", pinned_fraction=0.5)


# ---------------------------------------------------------------------------
# cached sampling through the loader: the acceptance bar
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,blocks", [("lru", 16), ("pinned", 16),
                                           ("lru", 6)])
def test_pallas_edgecached_bit_identity(small_graph, policy, blocks):
    """pallas@edgecache == pallas@full-upload, bit for bit — including a
    cache so small the planner must split every hop into chunks."""
    g = small_graph
    full = make_loader("pallas", g, batch_size=BATCH, fanouts=FANOUTS,
                       seed=0)
    cached = make_loader("pallas", g, batch_size=BATCH, fanouts=FANOUTS,
                         seed=0,
                         device_cache=_edge_tier(blocks, policy=policy))
    try:
        for i in range(3):
            a, b = full.get_batch(i), cached.get_batch(i)
            np.testing.assert_array_equal(a.targets, b.targets)
            for t, (x, y) in enumerate(zip(a.hop_ids, b.hop_ids)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                              err_msg=f"hop {t}")
            for t, (x, y) in enumerate(zip(a.hop_feats, b.hop_feats)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                              err_msg=f"hop {t}")
            np.testing.assert_array_equal(np.asarray(a.labels),
                                          np.asarray(b.labels))
            ec = b.trace.io["edgecache"]
            assert ec["hits"] + ec["misses"] > 0
        stats = cached.stats()["edgecache"]
        assert stats["capacity_rows"] == blocks
        assert stats["misses"] > 0
    finally:
        full.close()
        cached.close()


def test_pallas_edgecached_loss_trajectory_bit_identical(small_graph):
    g = small_graph

    def trajectory(loader):
        gnn = GraphSAGE(GNNConfig(feat_dim=g.feat_dim, hidden=16,
                                  n_classes=int(g.labels.max()) + 1,
                                  fanouts=FANOUTS))
        opt = adamw(3e-3)
        step = build_train_step(loader, gnn, opt)
        p = gnn.init(jax.random.key(0))
        state = {"params": p, "opt": opt.init(p),
                 "step": jnp.zeros((), jnp.int32)}
        losses = []
        train_loop(loader, step, state, steps=3,
                   on_step=lambda i, s, m: losses.append(
                       np.asarray(m["loss"])))
        return losses

    full = make_loader("pallas", g, batch_size=BATCH, fanouts=FANOUTS,
                       seed=0)
    cached = make_loader("pallas", g, batch_size=BATCH, fanouts=FANOUTS,
                         seed=0, device_cache=_edge_tier(16))
    try:
        la = trajectory(full)
        lb = trajectory(cached)
    finally:
        full.close()
        cached.close()
    np.testing.assert_array_equal(la, lb)


def test_combined_feature_and_topology_cache(small_graph, disk_dir):
    """One device tier covering both array families over a real DiskStore:
    every miss family is real paged disk I/O, and both counter blocks
    ride in the trace next to the host page-cache counters."""
    g = small_graph
    spec = PipelineSpec(
        backend=BackendSpec(name="pallas"),
        sampler=SamplerSpec(fanouts=FANOUTS),
        store=StoreSpec(kind="disk", path=disk_dir),
        cache_tiers=(
            CacheTierSpec(tier="host", capacity_mb=0.25, arrays=()),
            CacheTierSpec(tier="device", rows=24, edge_blocks=16,
                          arrays=("features", "topology"))),
        batch_size=BATCH, seed=0)
    full = make_loader("pallas", g, batch_size=BATCH, fanouts=FANOUTS,
                       seed=0)
    pipe = build_pipeline(spec, g)
    try:
        for i in range(2):
            a, b = full.get_batch(i), pipe.get_batch(i)
            for x, y in zip(a.hop_feats, b.hop_feats):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
            io = b.trace.io
            assert io["devcache"]["misses"] > 0
            assert io["edgecache"]["misses"] > 0
            assert io["block_fetches"] > 0       # host page-cache counters
    finally:
        full.close()
        pipe.close()


def test_edgecache_misses_are_real_paged_reads(small_graph, disk_dir):
    g = small_graph
    st = DiskStore(disk_dir, cache_mb=0.25)
    block_e = ops.edge_block_size(int(g.degrees().max()))
    dc = DeviceEdgeBlockCache(st, indptr=g.indptr, block_e=block_e,
                              blocks=8)
    io0 = st.io_counters()
    dc.resolve(np.arange(6))
    io1 = st.io_counters()
    assert io1["block_fetches"] > io0["block_fetches"]
    # contents still exact through the paged path
    table = np.asarray(dc.table)
    slots = np.asarray(dc.slot_of)
    np.testing.assert_array_equal(table[slots[0]],
                                  np.pad(g.indices[:block_e],
                                         (0, max(0, block_e - g.num_edges))
                                         )[:block_e])
    st.close()


def test_edgecached_under_prefetch_bit_identical(small_graph):
    """Edge-block admission in the prefetch worker must not change
    results."""
    g = small_graph
    sync = make_loader("pallas", g, batch_size=BATCH, fanouts=FANOUTS,
                       seed=0, device_cache=_edge_tier(16))
    pre = make_loader("pallas", g, batch_size=BATCH, fanouts=FANOUTS,
                      seed=0, prefetch=2, device_cache=_edge_tier(16))
    try:
        for i in range(3):
            a, b = sync.get_batch(i), pre.get_batch(i)
            for x, y in zip(a.hop_feats, b.hop_feats):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    finally:
        sync.close()
        pre.close()


def test_epoch_counters_cover_edgecache(small_graph):
    loader = make_loader("pallas", small_graph, batch_size=BATCH,
                         fanouts=FANOUTS, seed=0,
                         device_cache=_edge_tier(16))
    try:
        loader.get_batch(0)
        loader.start_epoch()
        loader.get_batch(1)
        s = loader.stats()
        assert s["edgecache_epoch"]["hits"] + \
            s["edgecache_epoch"]["misses"] > 0
        assert s["edgecache_epoch"]["misses"] <= s["edgecache"]["misses"]
    finally:
        loader.close()
