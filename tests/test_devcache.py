"""Device feature cache: residency/bit-identity of the HBM hot-row cache,
cache-policy edge cases shared with the host caches, the GraphSAINT
sampler family, per-epoch counters, and the sharded DiskStore lock."""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GNNConfig, GraphSAGE, build_train_step, make_loader,
                        train_loop)
from repro.optim import adamw
from repro.storage import (DeviceCacheSpec, DeviceFeatureCache, DiskStore,
                           LRUCache, PinnedCache, save_graph)

FANOUTS = (3, 2)
BATCH = 8


@pytest.fixture(scope="module")
def disk_dir(small_graph, tmp_path_factory):
    path = tmp_path_factory.mktemp("graphstore-dev")
    save_graph(small_graph, str(path))
    return str(path)


# ---------------------------------------------------------------------------
# DeviceFeatureCache core: residency + bit-identity of gathered rows
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,rows", [("lru", 64), ("pinned", 64),
                                         ("lru", 1), ("lru", 4096),
                                         ("pinned", 2)])
def test_gather_rows_bit_identity(small_graph, policy, rows):
    """Whatever the capacity — below one batch's working set, capacity-1,
    or larger than the whole table — the cache returns exactly the
    backing table's float32 rows."""
    g = small_graph
    dc = DeviceFeatureCache(g, rows=rows, policy=policy)
    rng = np.random.default_rng(rows)
    for i in range(3):
        ids = np.unique(rng.integers(0, g.num_nodes, 200))
        out = np.asarray(dc.gather_rows(ids))
        np.testing.assert_array_equal(out, g.features[ids])
    c = dc.counters()
    assert c["misses"] > 0
    assert c["hits"] + c["misses"] > 0


def test_full_residency_degenerates_to_no_evictions(small_graph):
    """A cache larger than the table: after one sweep everything is
    resident — the second sweep is all hits, zero misses/evictions."""
    g = small_graph
    dc = DeviceFeatureCache(g, rows=g.num_nodes + 10, policy="lru")
    all_ids = np.arange(g.num_nodes)
    dc.gather_rows(all_ids)
    c1 = dc.counters()
    assert c1["misses"] == g.num_nodes and c1["evictions"] == 0
    np.testing.assert_array_equal(np.asarray(dc.gather_rows(all_ids)),
                                  g.features)
    c2 = dc.counters()
    assert c2["misses"] == c1["misses"]          # no re-miss
    assert c2["evictions"] == 0
    assert c2["hits"] == c1["hits"] + g.num_nodes


def test_duplicate_ids_install_once(small_graph):
    """A repeated id in one resolve (the loader's pow2 dispatch padding)
    must install exactly once.  Double-installing leaves a ghost slot
    whose later eviction clears slot_of[id] while the id still counts as
    resident — the next gather of it would silently read cache row 0."""
    g = small_graph
    dc = DeviceFeatureCache(g, rows=4, policy="lru")
    dc.gather_rows(np.array([5, 5]))             # duplicate miss
    dc.gather_rows(np.array([6, 7]))             # fill remaining capacity
    # with a ghost, this batch would evict (5-ghost, 6) and corrupt 5
    out = np.asarray(dc.gather_rows(np.array([5, 8, 9])))
    np.testing.assert_array_equal(out, g.features[[5, 8, 9]])
    # and the mirror stayed consistent: one slot per resident id
    resident = dc._slot_entry[dc._slot_entry >= 0]
    assert len(set(resident.tolist())) == resident.size


def test_capacity_one_thrashes_but_stays_correct(small_graph):
    g = small_graph
    dc = DeviceFeatureCache(g, rows=1, policy="lru")
    ids = np.array([5, 9, 5, 9, 5])
    out = np.asarray(dc.gather_rows(ids))
    np.testing.assert_array_equal(out, g.features[ids])
    c = dc.counters()
    assert c["evictions"] >= c["misses"] - 1     # every admit displaces


def test_pinned_preload_and_hot_hits(small_graph):
    g = small_graph
    dc = DeviceFeatureCache(g, rows=32, policy="pinned")
    s = dc.stats()
    assert s["pinned_rows"] == 16 and s["preload_rows"] == 16
    hub = int(np.argmax(g.degrees()))
    c0 = dc.counters()
    np.testing.assert_array_equal(np.asarray(dc.gather_rows([hub]))[0],
                                  g.features[hub])
    c1 = dc.counters()
    assert c1["hits"] == c0["hits"] + 1          # staged, never fetched
    assert c1["misses"] == c0["misses"]


def test_pinned_set_exceeding_capacity_raises(small_graph):
    with pytest.raises(ValueError, match="pinned"):
        DeviceFeatureCache(small_graph, rows=16, policy="pinned",
                           pinned_fraction=2.0)
    with pytest.raises(ValueError, match="pinned"):
        PinnedCache(small_graph, 8, pinned_budget=9)


def test_host_lru_capacity_one_and_eviction_reporting():
    """Shared policy machinery edge case: a capacity-1 LRU thrashes
    without corrupting payloads, and ``put`` reports its victim."""
    c = LRUCache(1)
    assert c.put(7, "a") is None
    assert c.get(7) == "a"
    assert c.put(8, "b") == (7, "a")             # victim + payload back
    assert c.get(7) is None and c.get(8) == "b"
    assert c.evictions == 1


def test_disk_backed_misses_are_real_paged_reads(small_graph, disk_dir):
    g = small_graph
    st = DiskStore(disk_dir, cache_mb=0.25)
    dc = DeviceFeatureCache(st, rows=16, policy="lru")
    io0 = st.io_counters()
    ids = np.unique(np.random.default_rng(3).integers(0, g.num_nodes, 64))
    np.testing.assert_array_equal(np.asarray(dc.gather_rows(ids)),
                                  g.features[ids])
    io1 = st.io_counters()
    assert io1["block_fetches"] > io0["block_fetches"]
    st.close()


# ---------------------------------------------------------------------------
# pallas loader through the cache: the acceptance bar
# ---------------------------------------------------------------------------

def _loss_trajectory(loader, g, steps=3):
    gnn = GraphSAGE(GNNConfig(feat_dim=g.feat_dim, hidden=16,
                              n_classes=int(g.labels.max()) + 1,
                              fanouts=FANOUTS))
    opt = adamw(3e-3)
    step = build_train_step(loader, gnn, opt)
    p = gnn.init(jax.random.key(0))
    state = {"params": p, "opt": opt.init(p),
             "step": jnp.zeros((), jnp.int32)}
    losses = []
    state, _ = train_loop(loader, step, state, steps=steps,
                          on_step=lambda i, s, m: losses.append(
                              np.asarray(m["loss"])))
    return losses


@pytest.mark.parametrize("policy", ["lru", "pinned"])
def test_pallas_cached_loader_bit_identity(small_graph, policy):
    """pallas@cached == pallas@full-upload, bit for bit, with the device
    cache far below the unique-rows-per-batch working set."""
    g = small_graph
    full = make_loader("pallas", g, batch_size=BATCH, fanouts=FANOUTS,
                       seed=0)
    cached = make_loader("pallas", g, batch_size=BATCH, fanouts=FANOUTS,
                         seed=0,
                         device_cache=DeviceCacheSpec(rows=24, policy=policy))
    try:
        for i in range(3):
            a, b = full.get_batch(i), cached.get_batch(i)
            np.testing.assert_array_equal(a.targets, b.targets)
            for t, (x, y) in enumerate(zip(a.hop_ids, b.hop_ids)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                              err_msg=f"hop {t}")
            for t, (x, y) in enumerate(zip(a.hop_feats, b.hop_feats)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                              err_msg=f"hop {t}")
            np.testing.assert_array_equal(np.asarray(a.labels),
                                          np.asarray(b.labels))
            # counters ride in the trace, next to host-cache counters —
            # and count each unique row exactly once (dispatch padding
            # must not inflate hit rates)
            dc = b.trace.io["devcache"]
            assert dc["misses"] > 0
            uniq = np.unique(np.concatenate(
                [np.asarray(h).reshape(-1) for h in b.hop_ids]))
            assert dc["hits"] + dc["misses"] == uniq.size
        assert cached.stats()["devcache"]["evictions"] > 0
    finally:
        full.close()
        cached.close()


def test_pallas_cached_loss_trajectory_bit_identical(small_graph):
    g = small_graph
    full = make_loader("pallas", g, batch_size=BATCH, fanouts=FANOUTS,
                       seed=0)
    cached = make_loader("pallas", g, batch_size=BATCH, fanouts=FANOUTS,
                         seed=0, device_cache=DeviceCacheSpec(rows=24,
                                                              policy="lru"))
    try:
        la = _loss_trajectory(full, g)
        lb = _loss_trajectory(cached, g)
    finally:
        full.close()
        cached.close()
    np.testing.assert_array_equal(la, lb)


def test_pallas_cached_through_diskstore(small_graph, disk_dir):
    """The full device-side out-of-core path: HBM cache misses become
    real paged disk reads, both counter families land in the trace."""
    g = small_graph
    st = DiskStore(disk_dir, cache_mb=0.25)
    loader = make_loader("pallas", g, batch_size=BATCH, fanouts=FANOUTS,
                         seed=0, store=st,
                         device_cache=DeviceCacheSpec(rows=24, policy="lru"))
    full = make_loader("pallas", g, batch_size=BATCH, fanouts=FANOUTS,
                       seed=0)
    try:
        a, b = full.get_batch(0), loader.get_batch(0)
        for x, y in zip(a.hop_feats, b.hop_feats):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        io = b.trace.io
        assert io["devcache"]["misses"] > 0
        assert io["block_fetches"] > 0           # host page-cache counters
    finally:
        full.close()
        loader.close()
        st.close()


def test_pallas_cached_under_prefetch_bit_identical(small_graph):
    """Cache admission in the prefetch worker must not change results."""
    g = small_graph
    sync = make_loader("pallas", g, batch_size=BATCH, fanouts=FANOUTS,
                       seed=0, device_cache=DeviceCacheSpec(rows=24,
                                                            policy="lru"))
    pre = make_loader("pallas", g, batch_size=BATCH, fanouts=FANOUTS,
                      seed=0, prefetch=2,
                      device_cache=DeviceCacheSpec(rows=24, policy="lru"))
    try:
        for i in range(3):
            a, b = sync.get_batch(i), pre.get_batch(i)
            for x, y in zip(a.hop_feats, b.hop_feats):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    finally:
        sync.close()
        pre.close()


def test_device_cache_rejected_off_pallas(small_graph):
    with pytest.raises(ValueError, match="pallas"):
        make_loader("host", small_graph, batch_size=4, fanouts=FANOUTS,
                    device_cache=DeviceCacheSpec(rows=8))


# ---------------------------------------------------------------------------
# GraphSAINT sampler family
# ---------------------------------------------------------------------------

def test_saint_loader_shapes_and_training(small_graph):
    g = small_graph
    W = 3
    loader = make_loader("host", g, batch_size=BATCH, sampler="saint",
                         walk_length=W, seed=0)
    try:
        assert loader.fanouts == (W + 1,)
        mb = loader.get_batch(0)
        assert np.asarray(mb.hop_ids[0]).shape == (BATCH,)
        assert np.asarray(mb.hop_ids[1]).shape == (BATCH, W + 1)
        assert np.asarray(mb.hop_feats[1]).shape == (BATCH, W + 1, g.feat_dim)
        np.testing.assert_array_equal(
            np.asarray(mb.hop_feats[1]),
            g.features[np.asarray(mb.hop_ids[1])])
        # walks really follow edges: column 0 is the root itself
        np.testing.assert_array_equal(np.asarray(mb.hop_ids[1])[:, 0],
                                      np.asarray(mb.targets))
        losses = _loss_trajectory_saint(loader, g, W)
        assert np.isfinite(losses).all()
    finally:
        loader.close()


def _loss_trajectory_saint(loader, g, W, steps=2):
    gnn = GraphSAGE(GNNConfig(feat_dim=g.feat_dim, hidden=16,
                              n_classes=int(g.labels.max()) + 1,
                              fanouts=(W + 1,)))
    opt = adamw(3e-3)
    step = build_train_step(loader, gnn, opt)
    p = gnn.init(jax.random.key(0))
    state = {"params": p, "opt": opt.init(p),
             "step": jnp.zeros((), jnp.int32)}
    losses = []
    train_loop(loader, step, state, steps=steps,
               on_step=lambda i, s, m: losses.append(float(m["loss"])))
    return np.asarray(losses)


@pytest.mark.parametrize("backend", ["isp", "pallas"])
def test_saint_rejected_on_device_backends(small_graph, host_mesh, backend):
    with pytest.raises(ValueError, match="saint"):
        make_loader(backend, small_graph, batch_size=4, sampler="saint",
                    mesh=host_mesh)


# ---------------------------------------------------------------------------
# per-epoch cache counters
# ---------------------------------------------------------------------------

def test_epoch_counters_reset_per_epoch(small_graph, disk_dir):
    st = DiskStore(disk_dir, cache_mb=0.25)
    # single worker, depth-1 queue: production stays (nearly) in lockstep
    # with consumption, so the epoch boundary is sharp enough to test
    loader = make_loader("host", None, batch_size=BATCH, fanouts=FANOUTS,
                         seed=0, store=st, n_workers=1, queue_depth=1)
    try:
        for i in range(2):
            loader.get_batch(i)
        assert "store_epoch" not in loader.stats()
        loader.start_epoch()
        for i in range(2, 8):
            loader.get_batch(i)
        s = loader.stats()
        assert s["store_epoch"]["misses"] > 0
        # the epoch view excludes (at least) the warmup batches' misses,
        # which the cumulative view keeps (producers run ahead, so the
        # boundary is fuzzy by the pipeline depth — but never the whole
        # warmup)
        assert s["store_epoch"]["misses"] < s["store"]["misses"]
        # a new epoch mark restarts the window
        loader.start_epoch()
        s2 = loader.stats()
        assert s2["store_epoch"]["misses"] <= s["store_epoch"]["misses"]
    finally:
        loader.close()
        st.close()


def test_epoch_counters_cover_devcache(small_graph):
    loader = make_loader("pallas", small_graph, batch_size=BATCH,
                         fanouts=FANOUTS, seed=0,
                         device_cache=DeviceCacheSpec(rows=24, policy="lru"))
    try:
        loader.get_batch(0)
        loader.start_epoch()
        loader.get_batch(1)
        s = loader.stats()
        assert s["devcache_epoch"]["misses"] > 0
        assert s["devcache_epoch"]["misses"] < s["devcache"]["misses"]
    finally:
        loader.close()


# ---------------------------------------------------------------------------
# sharded DiskStore page-cache lock
# ---------------------------------------------------------------------------

def test_sharded_lock_serves_identical_data(small_graph, disk_dir):
    g = small_graph
    for shards in (1, 4):
        st = DiskStore(disk_dir, cache_mb=0.5, lock_shards=shards)
        assert st.lock_shards == shards
        for u in (0, 7, int(np.argmax(g.degrees()))):
            np.testing.assert_array_equal(st.neighbors(u), g.neighbors(u))
        np.testing.assert_array_equal(st.gather_features(np.arange(16)),
                                      g.features[:16])
        io = st.io_counters()
        assert io["block_fetches"] == io["misses"]
        st.close()


def test_sharded_lock_concurrent_producers(small_graph, disk_dir):
    """4 producer threads through one sharded store: every read is
    correct and the counters stay consistent."""
    g = small_graph
    st = DiskStore(disk_dir, cache_mb=0.5, lock_shards=4)
    errs = []

    def worker(seed):
        rng = np.random.default_rng(seed)
        for _ in range(20):
            ids = rng.integers(0, g.num_nodes, 16)
            try:
                np.testing.assert_array_equal(st.gather_features(ids),
                                              g.features[ids])
            except AssertionError as e:          # surfaced on the main thread
                errs.append(e)

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    io = st.io_counters()
    assert io["block_fetches"] == io["misses"]
    assert io["hits"] + io["misses"] >= io["requests"]
    st.close()
