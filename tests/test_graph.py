"""CSR graph construction + Kronecker fractal expansion properties."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (DATASETS, edges_to_csr, kronecker_expand,
                        load_dataset, rmat_graph)


def test_rmat_valid():
    g = rmat_graph(512, 4096, seed=0)
    g.validate()
    assert g.num_nodes == 512
    assert g.num_edges > 0


def test_kronecker_growth_and_densification():
    g = rmat_graph(512, 4096, seed=1)
    big = kronecker_expand(g, factor=4, seed=2, edge_keep=0.6)
    big.validate()
    assert big.num_nodes == 4 * g.num_nodes
    # densification power law: average degree must INCREASE (Fig. 13)
    assert (big.num_edges / big.num_nodes) > (g.num_edges / g.num_nodes)


def test_kronecker_preserves_power_law_shape():
    g = rmat_graph(1024, 16384, seed=3)
    big = kronecker_expand(g, factor=4, seed=4, edge_keep=0.5)
    # compare log-log degree-distribution slope sign / heavy tail
    for gr in (g, big):
        deg = gr.degrees()
        deg = deg[deg > 0]
        # heavy tail: max degree >> median degree
        assert deg.max() > 5 * np.median(deg)


@pytest.mark.parametrize("name", list(DATASETS))
def test_datasets_load(name):
    g = load_dataset(name)
    g.validate()
    assert g.features.shape == (g.num_nodes, DATASETS[name][2])
    assert g.labels.min() >= 0


def test_edge_byte_range_contiguous():
    g = rmat_graph(128, 1024, seed=5)
    prev_end = 0
    for u in range(g.num_nodes):
        lo, hi = g.edge_byte_range(u)
        assert lo == prev_end
        prev_end = hi
    assert prev_end == g.num_edges * 8


@given(st.integers(8, 64), st.integers(0, 200), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_edges_to_csr_invariants(n, m, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    g = edges_to_csr(src, dst, n)
    g.validate()
    # symmetric: u in N(v) <=> v in N(u)
    for u in range(min(n, 8)):
        for v in g.neighbors(u):
            assert u in g.neighbors(int(v))
    # no self loops, no duplicates
    for u in range(min(n, 8)):
        nb = g.neighbors(u)
        assert u not in nb
        assert len(set(nb.tolist())) == len(nb)
