"""Checkpointing: atomicity, async, resume determinism, elastic restore."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt


def _tree_eq(a, b):
    return all(np.allclose(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                        "b": jnp.zeros((3,))},
             "opt": {"m": {"w": jnp.ones((2, 3)), "b": jnp.ones((3,))}},
             "step": jnp.asarray(5, jnp.int32)}
    ckpt.save(str(tmp_path), 5, state)
    restored, step = ckpt.restore(str(tmp_path))
    assert step == 5
    assert _tree_eq(state, restored)


def test_atomic_no_partial_files(tmp_path):
    state = {"w": jnp.ones((4,))}
    ckpt.save(str(tmp_path), 1, state)
    files = os.listdir(tmp_path)
    assert not any(".tmp" in f for f in files), files


def test_async_and_prune(tmp_path):
    saver = ckpt.AsyncSaver(str(tmp_path))
    for s in (1, 2, 3, 4):
        saver.save_async(s, {"w": jnp.full((2,), float(s))})
    saver.wait()
    assert ckpt.list_steps(str(tmp_path)) == [1, 2, 3, 4]
    ckpt.prune(str(tmp_path), keep=2)
    assert ckpt.list_steps(str(tmp_path)) == [3, 4]
    restored, _ = ckpt.restore(str(tmp_path))
    assert float(restored["w"][0]) == 4.0


def test_resume_determinism(tmp_path):
    """Train 6 steps straight vs. 3 + checkpoint + restore + 3: identical."""
    from repro.core import (GNNConfig, GraphSAGE, ISPGraph,
                            build_isp_train_step, load_dataset,
                            partition_graph)
    from repro.launch.mesh import make_host_mesh
    from repro.optim import adamw

    g = load_dataset("reddit")
    mesh = make_host_mesh()
    engine = ISPGraph(partition_graph(g, 1), mesh)
    gnn = GraphSAGE(GNNConfig(feat_dim=g.feat_dim, hidden=16, n_classes=41,
                              fanouts=(3, 2)))
    opt = adamw(1e-3)
    step = jax.jit(build_isp_train_step(engine, gnn, opt, mesh, None,
                                        fanouts=(3, 2)))

    def targets(i):
        return jnp.asarray(np.random.default_rng(i).integers(0, g.num_nodes,
                                                             8), jnp.int32)

    def init():
        p = gnn.init(jax.random.key(0))
        return {"params": p, "opt": opt.init(p),
                "step": jnp.zeros((), jnp.int32)}

    with mesh:
        s1 = init()
        for i in range(6):
            s1, _ = step(s1, targets(i), jax.random.key(i))

        s2 = init()
        for i in range(3):
            s2, _ = step(s2, targets(i), jax.random.key(i))
        ckpt.save(str(tmp_path), 3, s2)
        s2, start = ckpt.restore(str(tmp_path))
        for i in range(int(start), 6):
            s2, _ = step(s2, targets(i), jax.random.key(i))

    assert _tree_eq(s1["params"], s2["params"])


ELASTIC_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import checkpoint as ckpt
from repro.launch.mesh import make_mesh

n = %d
mesh = make_mesh((n, 1), ("data", "model"))
sh = NamedSharding(mesh, P("data"))
d = sys.argv[1]
mode = sys.argv[2]
if mode == "save":
    state = {"w": jax.device_put(jnp.arange(32.0), sh)}
    ckpt.save(d, 1, state)
else:
    state, _ = ckpt.restore(d, shardings={"w": sh})
    assert state["w"].sharding.is_equivalent_to(sh, 1)
    assert np.allclose(np.asarray(state["w"]), np.arange(32.0))
    print("OK", n)
"""


@pytest.mark.parametrize("save_dev,restore_dev", [(8, 4), (4, 8)])
def test_elastic_restore_across_mesh_shapes(tmp_path, save_dev, restore_dev):
    """A checkpoint written on an N-device mesh restores onto an M-device
    mesh (elastic rescale / failure recovery onto a different slice)."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT % (save_dev, save_dev),
         str(tmp_path), "save"],
        capture_output=True, text=True, env=env, cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
    r = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT % (restore_dev, restore_dev),
         str(tmp_path), "restore"],
        capture_output=True, text=True, env=env, cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
    assert f"OK {restore_dev}" in r.stdout
