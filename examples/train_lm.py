"""Train any assigned LM architecture end-to-end (reduced config on CPU).

The exact same model/step/sharding code lowers the full configs on the
512-chip production mesh in the dry-run; here we run a real optimization
loop with checkpoint/auto-resume on a 2x1 CPU mesh.

Run:  PYTHONPATH=src python examples/train_lm.py [arch] [steps]
e.g.  PYTHONPATH=src python examples/train_lm.py mixtral-8x7b 30
"""

import os
import sys
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=2"

import jax

from repro.data import TokenPipeline
from repro.distributed.sharding import ShardingRules, named_sharding
from repro.launch.mesh import make_mesh
from repro.models.registry import get_config
from repro.models.transformer import LM
from repro.optim import adamw, warmup_cosine
from repro.train.steps import build_train_step, init_train_state

arch = sys.argv[1] if len(sys.argv) > 1 else "qwen2-0.5b"
steps = int(sys.argv[2]) if len(sys.argv) > 2 else 20

cfg = get_config(arch).reduced()
model = LM(cfg)
mesh = make_mesh((2, 1), ("data", "model"))
rules = ShardingRules.default()
print(f"{cfg.name}: {model.param_count()/1e6:.2f}M params, family={cfg.family}")

opt = adamw(warmup_cosine(3e-3, 5, steps))
step_fn = jax.jit(build_train_step(model, opt, mesh, rules, microbatches=2),
                  donate_argnums=0)
pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)

with mesh:
    state = init_train_state(model, opt, jax.random.key(0))
    shard = named_sharding(("batch", "seq"), rules, mesh)
    for i in range(steps):
        batch = pipe.jax_batch(i, {"tokens": shard, "labels": shard})
        state, m = step_fn(state, batch)
        if (i + 1) % 5 == 0:
            print(f"step {i+1:3d}  loss={float(m['loss']):.4f}  "
                  f"lr={float(m['lr']):.2e}")
print("done")
