"""Quickstart: SmartSAGE-on-TPU in ~60 lines.

Builds a Kronecker-expanded power-law graph and trains GraphSAGE through
the unified minibatch data plane: pick a data-preparation backend
(``host`` numpy pipeline, ``isp`` near-data mesh sampling, or ``pallas``
in-storage-style kernels) and every one feeds the same consumer with the
same ``Minibatch`` contract (the paper's backend comparison, live).

Run:  PYTHONPATH=src python examples/quickstart.py [backend]
"""

import os
import sys
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp

from repro.core import (GNNConfig, GraphSAGE, build_train_step, load_dataset,
                        make_loader, train_loop)
from repro.distributed.sharding import ShardingRules
from repro.launch.mesh import make_mesh
from repro.optim import adamw

BACKEND = sys.argv[1] if len(sys.argv) > 1 else "isp"
FANOUTS = (10, 5)
BATCH = 64
STEPS = 30

# 1. A power-law graph, fractally expanded (Table I methodology).
graph = load_dataset("reddit", large_scale=False)
print(f"graph: {graph.num_nodes} nodes, {graph.num_edges} edges, "
      f"{graph.feat_dim}-d features")

# 2. Mesh + the chosen data-preparation backend.  For `isp` the cold graph
#    lives sharded over the 'data' axis — the TPU analogue of the SSD; the
#    other backends run single-device data preparation.
mesh = make_mesh((4, 1), ("data", "model"))
loader = make_loader(BACKEND, graph, batch_size=BATCH, fanouts=FANOUTS,
                     mesh=mesh)
print(f"backend: {BACKEND}")

# 3. The shared GraphSAGE consumer: one jitted update step over whatever
#    Minibatch the backend produced (sample -> gather -> convolve -> AdamW).
gnn = GraphSAGE(GNNConfig(feat_dim=graph.feat_dim, hidden=128,
                          n_classes=int(graph.labels.max()) + 1,
                          fanouts=FANOUTS))
opt = adamw(1e-3)
rules = ShardingRules.default()
step = build_train_step(loader, gnn, opt, mesh, rules)

params = gnn.init(jax.random.key(0))
state = {"params": params, "opt": opt.init(params),
         "step": jnp.zeros((), jnp.int32)}


def log(i, state, m):
    if (i + 1) % 10 == 0:
        print(f"step {i+1:3d}  loss={float(m['loss']):.4f}  "
              f"acc={float(m['acc']):.3f}")


with mesh:
    state, stats = train_loop(loader, step, state, steps=STEPS, on_step=log)
loader.close()

print(f"{stats.steps_per_s:.2f} steps/s, consumer idle "
      f"{stats.idle_fraction:.1%}")
print("done — see examples/isp_vs_mmap.py for the storage-tier story")
