"""Quickstart: SmartSAGE-on-TPU in ~60 lines.

Builds a Kronecker-expanded power-law graph, partitions it over a 4-shard
mesh, and trains GraphSAGE with *near-data* (ISP-style) subgraph
generation: each shard samples the targets it owns and only the dense
subgraph + features cross the mesh (the paper's key data movement,
DESIGN.md §2).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (GNNConfig, GraphSAGE, ISPGraph,
                        build_isp_train_step, load_dataset, partition_graph)
from repro.distributed.sharding import ShardingRules
from repro.launch.mesh import make_mesh
from repro.optim import adamw

FANOUTS = (10, 5)
BATCH = 64
STEPS = 30

# 1. A power-law graph, fractally expanded (Table I methodology).
graph = load_dataset("reddit", large_scale=False)
print(f"graph: {graph.num_nodes} nodes, {graph.num_edges} edges, "
      f"{graph.feat_dim}-d features")

# 2. Mesh + contiguous node-range partitions (the 'data' axis is where the
#    cold graph lives — the TPU analogue of the SSD).
mesh = make_mesh((4, 1), ("data", "model"))
engine = ISPGraph(partition_graph(graph, 4), mesh)

# 3. GraphSAGE backend + fused near-data train step (one jit region:
#    sample -> gather -> convolve -> AdamW update).
gnn = GraphSAGE(GNNConfig(feat_dim=graph.feat_dim, hidden=128,
                          n_classes=int(graph.labels.max()) + 1,
                          fanouts=FANOUTS))
opt = adamw(1e-3)
rules = ShardingRules.default()
step = jax.jit(build_isp_train_step(engine, gnn, opt, mesh, rules, FANOUTS),
               donate_argnums=0)

state = {"params": gnn.init(jax.random.key(0)), "opt": None,
         "step": jnp.zeros((), jnp.int32)}
state["opt"] = opt.init(state["params"])

with mesh:
    for i in range(STEPS):
        targets = jnp.asarray(np.random.default_rng(i).integers(
            0, graph.num_nodes, BATCH), jnp.int32)
        state, m = step(state, targets, jax.random.key(i))
        if (i + 1) % 10 == 0:
            print(f"step {i+1:3d}  loss={float(m['loss']):.4f}  "
                  f"acc={float(m['acc']):.3f}")

print("done — see examples/isp_vs_mmap.py for the storage-tier story")
