"""The paper's experiment in one script: mmap-SSD vs SmartSAGE(SW) vs
SmartSAGE(HW/SW) vs DRAM/PMEM oracles, on a real sampler trace.

Replays GraphSAGE neighbor sampling (Algorithm 1) over a Kronecker
large-scale graph against each storage engine and prints the paper's
headline comparisons (Fig. 6/14/18 analogues).

Run:  PYTHONPATH=src python examples/isp_vs_mmap.py [dataset]
"""

import sys

import numpy as np

from repro.core import load_dataset, sample_khop
from repro.storage import ENGINES, e2e_train, make_engine

dataset = sys.argv[1] if len(sys.argv) > 1 else "reddit"
g = load_dataset(dataset, large_scale=True)
print(f"{g.name}: {g.num_nodes} nodes, {g.num_edges} edges "
      f"(avg degree {g.num_edges / g.num_nodes:.1f})\n")

rng = np.random.default_rng(0)
M = 1024
engines = {n: make_engine(n, g) for n in ENGINES}

# warm the stateful caches (page cache / scratchpad / FPGA DRAM)
for w in range(3):
    t = sample_khop(g, rng.integers(0, g.num_nodes, M), (25, 10), seed=w)
    for n in ("mmap", "directio", "fpga"):
        engines[n].batch_cost(t)

trace = sample_khop(g, rng.integers(0, g.num_nodes, M), (25, 10), seed=42)
print(f"one mini-batch (M={M}, fanouts 25x10): "
      f"{trace.touched_nodes.size} edge-list reads, "
      f"{sum(h.size for h in trace.hops[1:])} samples\n")

costs = {n: e.batch_cost(trace) for n, e in engines.items()}
base = costs["mmap"].time_s
print(f"{'engine':12s} {'sampling/batch':>14s} {'vs mmap':>8s} "
      f"{'link MB':>8s} {'I/O cmds':>9s}")
for n, c in costs.items():
    print(f"{n:12s} {c.time_s*1e3:11.1f} ms {base/c.time_s:7.1f}x "
          f"{c.link_bytes/1e6:8.2f} {c.commands:9d}")

print(f"\nSSD->host transfer reduction (mmap vs ISP): "
      f"{costs['mmap'].link_bytes / max(costs['isp'].link_bytes, 1):.1f}x "
      f"(paper: ~20x)")

print(f"\nend-to-end (12 producer workers, T4-class consumer):")
dram = e2e_train(engines["dram"], trace, workers=12)
for n in ("dram", "pmem", "mmap", "directio", "isp", "isp_oracle"):
    r = e2e_train(engines[n], trace, workers=12)
    print(f"{n:12s} {r.train_throughput:8.1f} batches/s  "
          f"GPU idle {r.gpu_idle_frac*100:5.1f}%  "
          f"(x{dram.train_throughput / r.train_throughput:.1f} slower "
          f"than DRAM oracle)")
