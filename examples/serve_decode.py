"""Batched serving with the near-data decode path.

Prefills a batch of prompts, then decodes greedily token-by-token against
the KV cache — the same ``build_serve_step`` the dry-run lowers for the
decode_32k / long_500k production cells (where the KV cache is sharded
over the 'model' axis and each shard reduces over its own slice — the
SmartSAGE near-data reduction applied to attention).

Run:  PYTHONPATH=src python examples/serve_decode.py [arch]
"""

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardingRules
from repro.launch.mesh import make_host_mesh
from repro.launch.shapes import make_batch
from repro.models.registry import get_config
from repro.models.transformer import LM
from repro.train.steps import build_prefill_step, build_serve_step

arch = sys.argv[1] if len(sys.argv) > 1 else "gemma3-1b"
B, PROMPT, GEN = 4, 32, 16

cfg = get_config(arch).reduced()
model = LM(cfg)
mesh = make_host_mesh()
rules = ShardingRules.default()
print(f"{cfg.name} (family={cfg.family}): batch={B}, prompt={PROMPT}, "
      f"gen={GEN}")

with mesh:
    params = model.init(jax.random.key(0))
    prefill = jax.jit(build_prefill_step(model, mesh, rules))
    serve = jax.jit(build_serve_step(model, mesh, rules), donate_argnums=(2,))

    batch = make_batch(cfg, B, PROMPT, kind="prefill")
    logits, cache = prefill(params, batch)

    def pad_cache(x):  # extend KV horizon for the generated tokens
        if x.ndim >= 3 and x.shape[2] == PROMPT:
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, GEN)
            return jnp.pad(x, pad)
        return x
    cache = jax.tree.map(pad_cache, cache)

    tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
    out = [tok]
    t0 = time.time()
    for i in range(GEN - 1):
        logits, cache, nxt = serve(params, tok, cache,
                                   jnp.asarray(PROMPT + i, jnp.int32))
        tok = nxt[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0

ids = np.concatenate([np.asarray(t) for t in out], axis=1)
print(f"decoded {GEN-1} steps x {B} seqs in {dt*1e3:.0f} ms "
      f"({(GEN-1)*B/dt:.1f} tok/s)")
for b in range(min(B, 2)):
    print(f"  seq{b}: {ids[b].tolist()}")
